"""Durable snapshots + elastic N->M resharding for the sharded dynamic
index (and the generic checkpoint store the train-side ``Checkpointer``
rides).

A production index must survive restarts and mesh resizes: everything
``ShardedDynamicIndex`` serves from is process-lifetime device state, so
this module gives it (1) crash-safe snapshots, (2) verified restore that is
*bit-exact* mid-churn, and (3) restore onto a different shard count that
reuses fitted state instead of rebuilding (the paper's lazy-reuse thesis
applied to operations: after a disruption, the cheap path is reusing fitted
leaves, not refitting them).

Snapshot layout and manifest schema (``SCHEMA`` below)::

    <dir>/step_00000042/            one committed snapshot
        manifest.json               commit record (see below)
        index.npz                   global arrays: splits, counter table,
                                    skew mutes
        shard_00000.npz ...         one file per shard: both tiers,
                                    tombstone bitmaps, fitted root/leaf
                                    params, error bounds, Lemma 4.1
                                    counters, window widths
        pool.npz                    optional: the replicated model pool

    manifest.json = {
      "schema": 1,                  manifest schema version — an unknown
                                    version is treated as corruption and
                                    falls back, never half-parsed
      "kind":   "sharded-dynamic-index" | "tree",
      "step":   int, "time": float,
      "meta":   free-form JSON the writer attached (for the sharded index:
                policies, per-shard scalar counters, build kwargs),
      "files":  {fname: {"md5": hex, "arrays": {name: {shape, dtype}}}},
    }

Durability contract (the invalidation rules a reader can rely on):

  * **Atomic commit**: a snapshot is written into ``step_*.tmp`` and
    ``os.replace``-renamed into place after every file and the manifest
    are on disk — a write killed mid-shard (crash, SIGKILL, fault
    injection) leaves only a ``.tmp`` directory that readers never see.
  * **Checksummed restore**: every file's md5 is recorded in the manifest
    at write time (over the exact bytes handed to the OS); restore
    re-hashes what it reads and raises :class:`SnapshotCorruption` on any
    mismatch, torn manifest, or missing file — corruption is *detected and
    reported*, never silently accepted.
  * **Latest-complete fallback**: :func:`restore_sharded` walks snapshots
    newest-to-oldest and serves the first one that verifies end-to-end;
    with ``on_corrupt="quarantine"`` a snapshot whose *shard files* are
    damaged restores anyway, replacing each damaged shard with a trivial
    empty shard (recorded in ``report.quarantined`` and
    ``index.quarantined``) — degraded serving: queries routed to a
    quarantined range answer not-found instead of sinking the process.
  * **Surfaced async errors**: the background writer never swallows a
    failure — it is recorded and re-raised from ``wait()`` or the next
    ``save()``; transient ``OSError``s retry with exponential backoff
    (``retries``/``backoff`` knobs) before being surfaced.

Bit-exactness: a snapshot taken between ``insert_batch`` calls restores to
identical ``find`` results on both the kernel and jnp paths.  Everything
the stacked dispatch consumes is either serialized verbatim (f64 tiers,
bitmaps, fitted params, error bounds, frozen routing scales, clamped
depths, counter table) or a pure deterministic function of it (tombstone
prefix sums, packed kernel tables, the per-shard slice stack) — so the
cold restack after restore reproduces the pre-crash device state bit for
bit.

Elastic resharding (:func:`reshard_sharded`, also the restore path when
the target mesh width differs from the snapshot): new boundaries are
balanced-live-count cuts snapped to duplicate-run starts (the
:func:`~repro.core.distributed.shard_bounds` invariant), old shards are
cut into boundary-aligned *pieces* with ``DynamicRMI.shed_prefix``/
``shed_suffix`` on clones (truncation or exact intercept shift — zero
refits), and each new shard keeps its largest piece as the *anchor* while
the other pieces' live keys ride the anchor's **delta tier** through the
ordinary routed merge — at worst triggering localized Lemma 4.1 rebuilds
of the seam-window leaves (out-of-range keys route to the anchor's edge
leaves under its frozen root).  ``ReshardStats`` pins the contract:
``full_rebuilds`` stays 0 (only trivial empty shards are ever built from
scratch) and ``leaf_refits`` counts the seam-leaf rebuilds.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import queue
import shutil
import threading
import time
from dataclasses import dataclass, field

import numpy as np

SCHEMA = 1
_STEP_FMT = "step_{:08d}"


class SnapshotError(IOError):
    """Base error of the persist layer."""


class SnapshotCorruption(SnapshotError):
    """A snapshot failed verification (torn manifest, checksum mismatch,
    missing file, unknown schema)."""


# ---------------------------------------------------------------------------
# Tree walkers (pluggable: dicts + NamedTuples, None-skipping) — shared by
# the train Checkpointer and the sharded snapshot below.
# ---------------------------------------------------------------------------
def tree_paths(tree, prefix: str = "") -> list:
    """Stable dotted path for every leaf (dicts + NamedTuples; ``None``
    subtrees are skipped)."""
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += tree_paths(tree[k], f"{prefix}{k}.")
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            out += tree_paths(getattr(tree, k), f"{prefix}{k}.")
    elif tree is None:
        pass
    else:
        out.append((prefix[:-1], tree))
    return out


def set_tree_path(tree, path: str, value):
    """Set ``path`` (dotted) in a dict/NamedTuple tree; returns a
    replacement node when an immutable (NamedTuple) root was rebuilt."""
    keys = path.split(".")

    def rec(node, i):
        k = keys[i]
        if isinstance(node, dict):
            if i == len(keys) - 1:
                node[k] = value
            else:
                repl = rec(node[k], i + 1)
                if repl is not None:       # immutable child replaced
                    node[k] = repl
            return None
        if hasattr(node, "_fields"):       # NamedTuple: immutable
            if i == len(keys) - 1:
                return node._replace(**{k: value})
            repl = rec(getattr(node, k), i + 1)
            return node._replace(**{k: repl}) if repl is not None else None
        return None

    return rec(tree, 0)


def get_tree_path(tree, path: str):
    node = tree
    for k in path.split("."):
        node = node[k] if isinstance(node, dict) else getattr(node, k)
    return node


# ---------------------------------------------------------------------------
# Array codec: npy/npz have no bf16 — view-cast to u16 and tag the dtype in
# the manifest so restore round-trips exactly.
# ---------------------------------------------------------------------------
def _encode_array(arr: np.ndarray) -> tuple[np.ndarray, str]:
    if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _decode_array(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        import ml_dtypes
        return arr.view(ml_dtypes.bfloat16)
    return arr


def _npz_key(name: str) -> str:
    # np.savez keywords cannot carry dots reliably; names round-trip via
    # the manifest, so the on-disk key just needs to be collision-free.
    return name.replace(".", "__")


def _write_bytes(path: str, data: bytes) -> None:
    """Single seam for every snapshot byte written to disk — the
    fault-injection harness (tests/faultinject.py) monkeypatches this to
    kill writes mid-file, tear manifests, or raise transient OSErrors."""
    with open(path, "wb") as f:
        f.write(data)


# ---------------------------------------------------------------------------
# The generic store.
# ---------------------------------------------------------------------------
@dataclass
class SnapshotStore:
    """Checksummed, atomically-committed snapshot directory with an async
    writer whose failures are surfaced, never swallowed.

    ``save`` takes ``files``: {fname: {array_name: np.ndarray}} — a fname
    ending in ``.npy`` holds exactly one array (under name ``""``), any
    other holds an npz of its dict.  ``retries`` extra transient-
    ``OSError`` attempts per file (= per shard) with ``backoff *
    2**attempt`` sleeps; the final failure is raised (blocking save) or
    recorded and re-raised from ``wait()``/the next ``save()`` (async)."""
    directory: str
    keep: int = 3
    retries: int = 0
    backoff: float = 0.05
    kind: str = "tree"
    write_retries: int = 0              # transient attempts that were retried
    _q: queue.Queue = None
    _thread: threading.Thread = None
    _error: BaseException = None
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._q = queue.Queue(maxsize=2)

    # -- write -------------------------------------------------------------
    def save(self, step: int, files: dict, meta: dict | None = None, *,
             blocking: bool = False) -> None:
        """Write one snapshot.  Async by default: the caller-side cost is
        materializing ``files``; a prior async failure is re-raised here
        so a failed snapshot can never be mistaken for durability."""
        self.raise_pending()
        if blocking:
            self._write(step, files, meta or {})
            return
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        self._q.put((step, files, meta or {}))

    def wait(self) -> None:
        """Block until queued snapshots are on disk; re-raise any writer
        failure."""
        if self._thread is not None:
            self._q.join()
        self.raise_pending()

    def raise_pending(self) -> None:
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise SnapshotError(
                f"async snapshot write failed: {err!r}") from err

    def _worker(self):
        while True:
            step, files, meta = self._q.get()
            try:
                self._write(step, files, meta)
            except BaseException as e:
                with self._lock:
                    self._error = e
            self._q.task_done()

    def _write(self, step: int, files: dict, meta: dict) -> None:
        self._write_once(step, files, meta)
        self._gc()

    def _retried_write(self, path: str, data: bytes) -> None:
        """Per-file (= per-shard) retry with exponential backoff on
        transient ``OSError``s; the final failure propagates."""
        for attempt in range(self.retries + 1):
            try:
                _write_bytes(path, data)
                return
            except OSError:
                if attempt >= self.retries:
                    raise
                self.write_retries += 1
                time.sleep(self.backoff * (2 ** attempt))

    def _write_once(self, step: int, files: dict, meta: dict) -> None:
        d = os.path.join(self.directory, _STEP_FMT.format(step) + ".tmp")
        shutil.rmtree(d, ignore_errors=True)    # stale tmp from a retry
        os.makedirs(d, exist_ok=True)
        manifest = {"schema": SCHEMA, "kind": self.kind, "step": step,
                    "time": time.time(), "meta": meta, "files": {}}
        for fname, arrays in files.items():
            buf = io.BytesIO()
            entry = {"arrays": {}}
            if fname.endswith(".npy"):
                (name, arr), = arrays.items()
                store, tag = _encode_array(np.asarray(arr))
                np.save(buf, store)
                entry["arrays"][name] = {"shape": list(np.shape(arr)),
                                         "dtype": tag}
            else:
                enc = {}
                for name, arr in arrays.items():
                    store, tag = _encode_array(np.asarray(arr))
                    enc[_npz_key(name)] = store
                    entry["arrays"][name] = {"shape": list(np.shape(arr)),
                                             "dtype": tag}
                np.savez(buf, **enc)
            data = buf.getvalue()
            entry["md5"] = hashlib.md5(data).hexdigest()
            self._retried_write(os.path.join(d, fname), data)
            manifest["files"][fname] = entry
        self._retried_write(os.path.join(d, "manifest.json"),
                            json.dumps(manifest).encode())
        final = os.path.join(self.directory, _STEP_FMT.format(step))
        shutil.rmtree(final, ignore_errors=True)   # re-save of same step
        os.replace(d, final)                       # atomic commit

    # -- read --------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, _STEP_FMT.format(step))

    def steps(self) -> list:
        """Committed snapshot steps, ascending (``.tmp`` dirs — torn
        writes — are never listed)."""
        out = []
        for s in os.listdir(self.directory):
            if s.startswith("step_") and not s.endswith(".tmp"):
                try:
                    out.append(int(s.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def _gc(self) -> None:
        for s in self.steps()[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def read_manifest(self, step: int) -> dict:
        """Parse + validate a snapshot's manifest; any defect (missing,
        torn JSON, unknown schema, bad structure) is SnapshotCorruption."""
        path = os.path.join(self._step_dir(step), "manifest.json")
        try:
            with open(path, "rb") as f:
                manifest = json.loads(f.read())
        except (OSError, ValueError) as e:
            raise SnapshotCorruption(
                f"step {step}: unreadable manifest: {e!r}") from e
        if not isinstance(manifest, dict) or \
                manifest.get("schema") != SCHEMA or \
                not isinstance(manifest.get("files"), dict):
            raise SnapshotCorruption(
                f"step {step}: manifest schema mismatch "
                f"(got {manifest.get('schema')!r}, want {SCHEMA})")
        return manifest

    def load_file(self, step: int, fname: str, manifest: dict | None = None,
                  *, verify: bool = True) -> dict:
        """Load one snapshot file as {array_name: np.ndarray}, re-hashing
        the bytes read against the manifest md5 (any mismatch, missing
        file, or undecodable payload is SnapshotCorruption)."""
        if manifest is None:
            manifest = self.read_manifest(step)
        entry = manifest["files"].get(fname)
        if entry is None:
            raise SnapshotCorruption(
                f"step {step}: {fname} not in manifest")
        try:
            with open(os.path.join(self._step_dir(step), fname), "rb") as f:
                data = f.read()
        except OSError as e:
            raise SnapshotCorruption(
                f"step {step}: missing file {fname}: {e!r}") from e
        if verify and hashlib.md5(data).hexdigest() != entry["md5"]:
            raise SnapshotCorruption(
                f"step {step}: checksum mismatch for {fname}")
        try:
            if fname.endswith(".npy"):
                (name, spec), = entry["arrays"].items()
                arr = np.load(io.BytesIO(data), allow_pickle=False)
                return {name: _decode_array(arr, spec["dtype"])}
            z = np.load(io.BytesIO(data), allow_pickle=False)
            return {name: _decode_array(z[_npz_key(name)], spec["dtype"])
                    for name, spec in entry["arrays"].items()}
        except Exception as e:
            raise SnapshotCorruption(
                f"step {step}: undecodable payload in {fname}: {e!r}") from e


# ---------------------------------------------------------------------------
# Sharded dynamic index snapshots.
# ---------------------------------------------------------------------------
KIND_SHARDED = "sharded-dynamic-index"
KIND_DYNAMIC = "dynamic-index"
_SHARD_FMT = "shard_{:05d}.npz"

_SHARD_SCALARS = (
    "eps", "route_n", "base_n", "base_dead_count", "delta_live",
    "delta_dead_count", "delta_compactions", "rebuilds", "deleted",
    "capacity_shrinks")
_IDX_COUNTERS = (
    "rebalances", "migrations_incremental", "migrations_full",
    "restack_full", "restack_rows", "capacity_shrinks",
    "swaps_committed")


def _params_to(arrays: dict, prefix: str, params) -> None:
    for path, arr in tree_paths(params):
        arrays[prefix + path] = np.asarray(arr)


def _params_from(arrays: dict, prefix: str, kind: str):
    import jax.numpy as jnp
    from . import models
    if kind == "linear":
        return models.LinearParams(a=jnp.asarray(arrays[prefix + "a"]),
                                   b=jnp.asarray(arrays[prefix + "b"]))
    return models.MLPParams(**{k: jnp.asarray(arrays[prefix + k])
                               for k in ("w1", "b1", "w2", "b2")})


def _shard_arrays(d) -> tuple[dict, dict]:
    """(arrays, meta) for one ``DynamicRMI``.  Host-mutable numpy state is
    copied (the async writer races later churn); device arrays are
    immutable and referenced as-is.  Tombstone prefix sums, packed kernel
    tables, and f32-exactness flags are derived state — recomputed on
    restore from the same inputs, hence bit-identical."""
    idx = d.index
    arrays = {
        "base_keys": np.asarray(idx.keys),
        "base_dead": np.asarray(d.base_dead),
        "err_lo": np.asarray(idx.err_lo),
        "err_hi": np.asarray(idx.err_hi),
        "reused_mask": np.asarray(idx.reused_mask),
        "leaf_sim": np.asarray(idx.leaf_sim),
        "delta_keys": np.asarray(d.delta_keys),
        "delta_leaf": np.asarray(d.delta_leaf),
        "delta_dead": np.asarray(d.delta_dead),
        "n_inserts": d.n_inserts.copy(),
        "budget": d.budget.copy(),
        "win": d._win.copy(),
    }
    _params_to(arrays, "root.", idx.root)
    _params_to(arrays, "leaves.", idx.leaves)
    meta = {k: _json_scalar(getattr(d, k)) for k in _SHARD_SCALARS}
    meta.update(root_kind=idx.root_kind, leaf_kind=idx.leaf_kind,
                n_leaves=int(idx.n_leaves),
                compact_dead_ratio=_json_scalar(d.compact_dead_ratio),
                reuse_on_rebuild=d.reuse_on_rebuild,
                build_kwargs=d.build_kwargs,
                swap_on_drift=bool(d.swap_on_drift),
                swaps_committed=int(d.swaps_committed),
                swap_rejects=int(d.swap_rejects))
    if d.drift is not None:
        # Raw counts are the drift monitor's whole state; score/latch are
        # tiny scalars synced here so restore needs no recompute pass.
        arrays["drift.ref"] = np.asarray(d.drift.ref)
        arrays["drift.acc"] = np.asarray(d.drift.acc)
        meta["drift"] = {
            "m": int(d.drift.m), "lo": float(d.drift.lo),
            "hi": float(d.drift.hi),
            "thresh_hi": float(d.drift.thresh_hi),
            "thresh_lo": float(d.drift.thresh_lo),
            "score": float(d.drift.score),
            "drifted": bool(d.drift.drifted),
            "updates": int(d.drift.updates),
            "rebaselines": int(d.drift.rebaselines)}
    return arrays, meta


def _json_scalar(v):
    if v is None or isinstance(v, (bool, str)):
        return v
    if isinstance(v, (int, np.integer)):
        return int(v)
    return float(v)


def _restore_shard(arrays: dict, meta: dict, pool):
    """Rebuild one ``DynamicRMI`` from its snapshot arrays.  Everything
    derived (psums, clamped depth, packed tables) is recomputed from the
    serialized state, which the bit-exactness contract relies on."""
    import jax.numpy as jnp
    from . import rmi as rmi_mod
    from .bounds import clamped_depth
    from .updates import DynamicRMI, _psum

    index = rmi_mod.RMIIndex(
        keys=jnp.asarray(arrays["base_keys"]),
        root_kind=meta["root_kind"],
        root=_params_from(arrays, "root.", meta["root_kind"]),
        leaf_kind=meta["leaf_kind"],
        leaves=_params_from(arrays, "leaves.", meta["leaf_kind"]),
        err_lo=jnp.asarray(arrays["err_lo"]),
        err_hi=jnp.asarray(arrays["err_hi"]),
        n_leaves=int(meta["n_leaves"]),
        reused_mask=jnp.asarray(arrays["reused_mask"]),
        leaf_sim=jnp.asarray(arrays["leaf_sim"]))
    base_dead = jnp.asarray(arrays["base_dead"])
    delta_dead = jnp.asarray(arrays["delta_dead"])
    d = DynamicRMI(
        index=index, pool=pool, eps=float(meta["eps"]),
        route_n=int(meta["route_n"]),
        delta_keys=jnp.asarray(arrays["delta_keys"]),
        delta_leaf=jnp.asarray(arrays["delta_leaf"]),
        delta_dead=delta_dead, delta_psum=_psum(delta_dead),
        delta_live=int(meta["delta_live"]),
        delta_dead_count=int(meta["delta_dead_count"]),
        compact_dead_ratio=meta["compact_dead_ratio"],
        delta_compactions=int(meta["delta_compactions"]),
        base_n=int(meta["base_n"]), base_dead=base_dead,
        base_psum=_psum(base_dead),
        base_dead_count=int(meta["base_dead_count"]),
        n_inserts=np.asarray(arrays["n_inserts"], np.int64),
        budget=np.asarray(arrays["budget"], np.float64),
        rebuilds=int(meta["rebuilds"]), deleted=int(meta["deleted"]),
        reuse_on_rebuild=meta["reuse_on_rebuild"],
        build_kwargs=dict(meta["build_kwargs"]))
    d.capacity_shrinks = int(meta.get("capacity_shrinks", 0))
    # Drift-monitor state (meta.get: snapshots predating the drift schema
    # restore with monitoring off, same backward-compat rule as
    # capacity_shrinks).
    d.swap_on_drift = bool(meta.get("swap_on_drift", False))
    d.swaps_committed = int(meta.get("swaps_committed", 0))
    d.swap_rejects = int(meta.get("swap_rejects", 0))
    dm = meta.get("drift")
    if dm is not None:
        from . import drift as drift_mod
        d.drift = drift_mod.DriftState(
            m=int(dm["m"]), lo=float(dm["lo"]), hi=float(dm["hi"]),
            thresh_hi=float(dm["thresh_hi"]),
            thresh_lo=float(dm["thresh_lo"]),
            ref=jnp.asarray(arrays["drift.ref"]),
            acc=jnp.asarray(arrays["drift.acc"]),
            score=jnp.float64(dm["score"]),
            drifted=jnp.asarray(bool(dm["drifted"])),
            updates=int(dm["updates"]),
            rebaselines=int(dm["rebaselines"]))
    d._win = np.asarray(arrays["win"], np.float64)
    index._iters = clamped_depth(d._win, index.n)
    return d


def _pool_files(pool) -> tuple[dict, dict]:
    arrays = {"hists": np.asarray(pool.hists),
              "err_lo": np.asarray(pool.err_lo),
              "err_hi": np.asarray(pool.err_hi)}
    _params_to(arrays, "params.", pool.params)
    _params_to(arrays, "domains.", pool.domains)
    meta = {"eps": float(pool.eps), "m": int(pool.m), "kind": pool.kind,
            "reuse_count": int(pool.reuse_count),
            "trained_count": int(pool.trained_count)}
    return arrays, meta


def _restore_pool(arrays: dict, meta: dict):
    import jax.numpy as jnp
    from .adapt import DomainSpec
    from .reuse import ModelPool
    domains = DomainSpec(**{k: jnp.asarray(arrays["domains." + k])
                            for k in DomainSpec._fields})
    return ModelPool(
        eps=meta["eps"], m=meta["m"], kind=meta["kind"],
        hists=jnp.asarray(arrays["hists"]),
        params=_params_from(arrays, "params.", meta["kind"]),
        err_lo=jnp.asarray(arrays["err_lo"]),
        err_hi=jnp.asarray(arrays["err_hi"]), domains=domains,
        reuse_count=meta["reuse_count"],
        trained_count=meta["trained_count"])


def snapshot_sharded(store: SnapshotStore, step: int, idx, *,
                     blocking: bool = False,
                     include_pool: bool = True) -> None:
    """Snapshot a ``ShardedDynamicIndex``: one npz per shard + global
    arrays + (optionally) the replicated pool, checksummed and atomically
    committed by ``store``.  Async by default — every host-mutable array
    is copied before this returns, so churn may continue immediately."""
    store.kind = KIND_SHARDED
    files = {"index.npz": {
        "splits": np.asarray(idx.splits, np.float64).copy(),
        "counts": np.asarray(idx._counts),
        "muted": np.asarray(idx._muted)}}
    shard_meta = []
    for s, d in enumerate(idx.shards):
        arrays, m = _shard_arrays(d)
        files[_SHARD_FMT.format(s)] = arrays
        shard_meta.append(m)
    meta = {
        "axis": idx.axis, "eps": float(idx.eps),
        "n_leaves": int(idx.n_leaves), "n_shards": int(idx.n_shards),
        "rebalance_ratio": _json_scalar(idx.rebalance_ratio),
        "rebalance_skew": float(idx.rebalance_skew),
        "migrate_headroom_factor": float(idx.migrate_headroom_factor),
        "build_kwargs": idx.build_kwargs,
        "counters": {k: int(getattr(idx, k)) for k in _IDX_COUNTERS},
        "shards": shard_meta,
    }
    if include_pool and idx.pool is not None:
        arrays, pm = _pool_files(idx.pool)
        files["pool.npz"] = arrays
        meta["pool"] = pm
    store.save(step, files, meta, blocking=blocking)


def snapshot_dynamic(store: SnapshotStore, step: int, d, *,
                     blocking: bool = False,
                     include_pool: bool = True) -> None:
    """Snapshot a single-host ``DynamicRMI`` (the ``repro.api.Index``
    local backend): the same per-shard array/meta schema as one shard of
    :func:`snapshot_sharded` — both tiers, tombstones, fitted params,
    Lemma 4.1 counters, window widths, and the drift-monitor state —
    checksummed and atomically committed by ``store``."""
    store.kind = KIND_DYNAMIC
    arrays, m = _shard_arrays(d)
    files = {_SHARD_FMT.format(0): arrays}
    meta = {"shard": m}
    if include_pool and d.pool is not None:
        parr, pm = _pool_files(d.pool)
        files["pool.npz"] = parr
        meta["pool"] = pm
    store.save(step, files, meta, blocking=blocking)


def restore_dynamic(store: SnapshotStore, *, step: int | None = None,
                    on_corrupt: str = "fallback"):
    """Restore a single-host ``DynamicRMI`` from the newest verifiable
    :func:`snapshot_dynamic` snapshot (or exactly ``step``), with the
    same latest-complete fallback contract as :func:`restore_sharded`
    (``"fallback"`` skips damaged snapshots, ``"raise"`` does not).
    Returns (index, restored step)."""
    if on_corrupt not in ("fallback", "raise"):
        raise ValueError(f"unknown on_corrupt={on_corrupt!r}")
    candidates = [step] if step is not None else \
        list(reversed(store.steps()))
    if not candidates:
        raise SnapshotError(f"no snapshots in {store.directory}")
    last_err = None
    for cand in candidates:
        try:
            manifest = store.read_manifest(cand)
            if manifest.get("kind") != KIND_DYNAMIC:
                raise SnapshotCorruption(
                    f"step {cand}: kind {manifest.get('kind')!r} is not "
                    f"{KIND_DYNAMIC!r}")
            meta = manifest["meta"]
            pool = None
            if "pool" in meta:
                pool = _restore_pool(
                    store.load_file(cand, "pool.npz", manifest),
                    meta["pool"])
            d = _restore_shard(
                store.load_file(cand, _SHARD_FMT.format(0), manifest),
                meta["shard"], pool)
            return d, cand
        except SnapshotCorruption as e:
            last_err = e
            if on_corrupt == "raise" or step is not None:
                raise
    raise SnapshotCorruption(
        f"no verifiable snapshot among steps "
        f"{sorted(candidates)}: last error: {last_err}")


@dataclass
class ReshardStats:
    """Work accounting of one elastic N->M reshard.  The no-full-rebuild
    contract is ``full_rebuilds == 0`` (only trivial *empty* shards are
    ever built from scratch — ``empty_builds``); ``leaf_refits`` counts the
    localized Lemma 4.1 seam-leaf rebuilds the delta-riding merges
    triggered."""
    n_from: int = 0
    n_to: int = 0
    pieces: int = 0             # boundary-aligned (old shard, new shard)
                                # overlaps extracted via clone + shed
    delta_merges: int = 0       # donor segments merged via the delta tier
    moved_keys: int = 0         # live keys that changed owning structure
    leaf_refits: int = 0        # Lemma 4.1 leaf rebuilds during the merges
    empty_builds: int = 0       # trivial empty shards built
    full_rebuilds: int = 0      # from-scratch builds of NON-empty shards
                                # (always 0 — pinned by tests)


@dataclass
class RestoreReport:
    """What :func:`restore_sharded` actually did."""
    step: int = -1
    n_shards_from: int = 0      # shard count in the snapshot
    n_shards: int = 0           # shard count served (the target mesh)
    quarantined: list = field(default_factory=list)
    skipped: list = field(default_factory=list)   # [(step, reason), ...]
    reshard: ReshardStats | None = None


def _empty_shard(eps, n_leaves, pool, build_kwargs):
    import jax.numpy as jnp
    from .updates import DynamicRMI
    # a shard's recorded build_kwargs may already pin n_leaves (DynamicRMI
    # folds it into rmi_kwargs) — explicit args win
    kw = dict(build_kwargs)
    kw["n_leaves"] = n_leaves
    return DynamicRMI.build(jnp.zeros((0,), jnp.float64), pool=pool,
                            eps=eps, **kw)


def _reshard_pieces(shards: list, n_to: int, *, eps, n_leaves, pool,
                    build_kwargs) -> tuple[list, np.ndarray, ReshardStats]:
    """Cut N fitted shards into M at duplicate-run-safe boundaries.

    Cuts are balanced-live-count positions snapped to run starts.  Each new
    shard keeps its largest overlapping piece as the *anchor* — extracted
    by ``shed_prefix``/``shed_suffix`` on a clone (truncation / exact
    intercept shift, zero refits) — and the remaining overlap segments'
    live keys merge into the anchor's delta tier through the ordinary
    routed ``insert_batch``, refitting only the seam-window leaves whose
    Lemma 4.1 budgets trip.  Input shard objects are consumed.  Returns
    (new shards, new splits, stats)."""
    n_from = len(shards)
    stats = ReshardStats(n_from=n_from, n_to=n_to)
    lc = np.asarray([d.live_count for d in shards], np.int64)
    total = int(lc.sum())
    if total == 0:
        stats.empty_builds = n_to
        return ([_empty_shard(eps, n_leaves, pool, build_kwargs)
                 for _ in range(n_to)],
                np.full((n_to - 1,), -np.inf, np.float64), stats)
    glive = np.concatenate([d.live_keys() for d in shards])
    offs = np.concatenate([[0], np.cumsum(lc)])
    cuts = np.empty((n_to + 1,), np.int64)
    cuts[0], cuts[-1] = 0, total
    for t in range(1, n_to):
        p = min(round(total * t / n_to), total)
        if 0 < p < total:
            # snap to the start of the equal-key run so a duplicate run
            # never straddles a shard seam (the shard_bounds invariant).
            p = int(np.searchsorted(glive, glive[p], side="left"))
        cuts[t] = p
    cuts = np.maximum.accumulate(cuts)
    splits = np.asarray([glive[cuts[t] - 1] if cuts[t] > 0 else -np.inf
                         for t in range(1, n_to)], np.float64)

    new_shards = []
    for t in range(n_to):
        lo, hi = int(cuts[t]), int(cuts[t + 1])
        if hi <= lo:
            new_shards.append(_empty_shard(eps, n_leaves, pool,
                                           build_kwargs))
            stats.empty_builds += 1
            continue
        over = [s for s in range(n_from)
                if lc[s] > 0 and offs[s] < hi and offs[s + 1] > lo]
        stats.pieces += len(over)
        counts = {s: int(min(offs[s + 1], hi) - max(offs[s], lo))
                  for s in over}
        s_star = max(over, key=counts.__getitem__)
        a_lo = int(max(offs[s_star], lo))
        a_hi = int(min(offs[s_star + 1], hi))
        # A whole-shard anchor is consumed as-is; a partial one is cut out
        # of a clone so sibling destinations keep their own pieces.
        anchor = shards[s_star] if counts[s_star] == int(lc[s_star]) \
            else shards[s_star].clone()
        if a_lo > offs[s_star]:
            anchor.shed_prefix(float(glive[a_lo - 1]))
        if a_hi < offs[s_star + 1]:
            anchor.shed_suffix(float(glive[a_hi - 1]))
        rb0 = anchor.rebuilds
        for seg_lo, seg_hi in ((lo, a_lo), (a_hi, hi)):
            if seg_hi > seg_lo:
                anchor.insert_batch(glive[seg_lo:seg_hi])
                stats.delta_merges += 1
                stats.moved_keys += seg_hi - seg_lo
        stats.leaf_refits += anchor.rebuilds - rb0
        new_shards.append(anchor)
    return new_shards, splits, stats


def reshard_sharded(idx, mesh, axis: str | None = None):
    """Elastic N->M reshard of a live ``ShardedDynamicIndex`` onto
    ``mesh`` without a from-scratch rebuild (see :func:`_reshard_pieces`).
    The input index is consumed.  Returns (new index, ReshardStats)."""
    from .distributed import ShardedDynamicIndex
    axis = axis or idx.axis
    n_to = mesh.shape[axis]
    shards, splits, stats = _reshard_pieces(
        idx.shards, n_to, eps=idx.eps, n_leaves=idx.n_leaves, pool=idx.pool,
        build_kwargs=idx.build_kwargs)
    out = ShardedDynamicIndex(
        mesh=mesh, axis=axis, splits=splits, shards=shards, eps=idx.eps,
        n_leaves=idx.n_leaves, pool=idx.pool,
        rebalance_ratio=idx.rebalance_ratio,
        rebalance_skew=idx.rebalance_skew,
        migrate_headroom_factor=idx.migrate_headroom_factor,
        build_kwargs=idx.build_kwargs)
    out._init_maintenance()
    return out, stats


def restore_sharded(store: SnapshotStore, mesh, axis: str = "data", *,
                    step: int | None = None, on_corrupt: str = "fallback"):
    """Restore a ``ShardedDynamicIndex`` from the newest verifiable
    snapshot in ``store`` (or exactly ``step`` when given), resharding to
    ``mesh``'s width when it differs from the snapshot's shard count.

    ``on_corrupt`` decides what a damaged snapshot costs:
      * ``"fallback"`` (default): a snapshot failing verification anywhere
        is skipped and the next-older one is tried (recorded in
        ``report.skipped``); raises :class:`SnapshotCorruption` when none
        survive.
      * ``"raise"``: the newest (or requested) snapshot must verify.
      * ``"quarantine"``: torn manifests / global files still fall back,
        but a snapshot whose *shard files* are damaged restores anyway —
        each damaged shard becomes a trivial empty shard listed in
        ``report.quarantined`` and ``index.quarantined``, and queries
        routed to its range answer found=False (degraded serving).

    Returns (index, :class:`RestoreReport`)."""
    if on_corrupt not in ("fallback", "raise", "quarantine"):
        raise ValueError(f"unknown on_corrupt={on_corrupt!r}")
    report = RestoreReport()
    candidates = [step] if step is not None else \
        list(reversed(store.steps()))
    if not candidates:
        raise SnapshotError(f"no snapshots in {store.directory}")
    last_err = None
    for cand in candidates:
        try:
            idx, rep = _restore_one(store, cand, mesh, axis, on_corrupt)
            rep.skipped = report.skipped
            return idx, rep
        except SnapshotCorruption as e:
            last_err = e
            report.skipped.append((cand, str(e)))
            if on_corrupt == "raise" or step is not None:
                raise
    raise SnapshotCorruption(
        f"no verifiable snapshot among steps "
        f"{sorted(c for c in candidates)}: last error: {last_err}")


def _restore_one(store: SnapshotStore, step: int, mesh, axis: str,
                 on_corrupt: str):
    import jax.numpy as jnp
    from .distributed import ShardedDynamicIndex
    manifest = store.read_manifest(step)
    if manifest.get("kind") != KIND_SHARDED:
        raise SnapshotCorruption(
            f"step {step}: kind {manifest.get('kind')!r} is not "
            f"{KIND_SHARDED!r}")
    meta = manifest["meta"]
    n_from = int(meta["n_shards"])
    glob = store.load_file(step, "index.npz", manifest)
    pool = None
    if "pool" in meta:
        pool = _restore_pool(store.load_file(step, "pool.npz", manifest),
                             meta["pool"])
    report = RestoreReport(step=step, n_shards_from=n_from,
                           n_shards=mesh.shape[axis])
    shards = []
    for s in range(n_from):
        sm = meta["shards"][s]
        try:
            shards.append(_restore_shard(
                store.load_file(step, _SHARD_FMT.format(s), manifest),
                sm, pool))
        except SnapshotCorruption as e:
            if on_corrupt != "quarantine":
                raise
            shards.append(_empty_shard(
                float(sm["eps"]), int(sm["n_leaves"]), pool,
                dict(sm["build_kwargs"])))
            report.quarantined.append((s, str(e)))
    quarantined_ids = [s for s, _ in report.quarantined]

    n_to = mesh.shape[axis]
    if n_to == n_from:
        splits = np.asarray(glob["splits"], np.float64).copy()
    else:
        shards, splits, stats = _reshard_pieces(
            shards, n_to, eps=float(meta["eps"]),
            n_leaves=int(meta["n_leaves"]), pool=pool,
            build_kwargs=dict(meta["build_kwargs"]))
        report.reshard = stats
    idx = ShardedDynamicIndex(
        mesh=mesh, axis=axis, splits=splits, shards=shards,
        eps=float(meta["eps"]), n_leaves=int(meta["n_leaves"]), pool=pool,
        rebalance_ratio=meta["rebalance_ratio"],
        rebalance_skew=float(meta["rebalance_skew"]),
        migrate_headroom_factor=float(meta["migrate_headroom_factor"]),
        build_kwargs=dict(meta["build_kwargs"]))
    for k, v in meta.get("counters", {}).items():
        if hasattr(idx, k):
            setattr(idx, k, int(v))
    idx._init_maintenance()
    if n_to == n_from:
        # Same-width restore is verbatim: the counter table recomputed by
        # _init_maintenance from the round-tripped scalars is bit-identical
        # to the saved one; skew mutes restore as saved (quarantined rows
        # re-arm).
        muted = jnp.asarray(np.asarray(glob["muted"], np.int64))
        if quarantined_ids:
            muted = muted.at[jnp.asarray(quarantined_ids)].set(-1)
        idx._muted = muted
        idx.quarantined = list(quarantined_ids)
    else:
        idx.quarantined = []
    return idx, report
