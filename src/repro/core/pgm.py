"""PGM-style baseline (paper competitor #4): piecewise-linear model index
with a worst-case error bound per segment, built bottom-up.

Segments come from the streaming shrinking-cone PLA (O(n), single pass,
NumPy on host — matching the reference PGM's build style); the recursion
indexes segment start keys with the same construction until one segment
remains. Lookup descends the hierarchy with eps-bounded searches, then
binary-searches the final +-eps window (jitted, vectorized).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .rmi import bounded_search, verified_search

Array = jax.Array


def _shrinking_cone(keys: np.ndarray, eps: int):
    """Greedy PLA: (starts, slopes) s.t. the line through (keys[start],
    start) with the cone slope predicts every member rank within +-eps."""
    n = keys.size
    starts, slopes = [0], []
    lo, hi = -np.inf, np.inf          # slope cone
    x0, y0 = keys[0], 0
    for i in range(1, n):
        x = keys[i]
        if x == x0:
            continue
        dx = x - x0
        s_lo, s_hi = (i - y0 - eps) / dx, (i - y0 + eps) / dx
        nlo, nhi = max(lo, s_lo), min(hi, s_hi)
        if nlo > nhi:                 # cone collapsed -> close segment
            slopes.append(_mid(lo, hi))
            starts.append(i)
            x0, y0 = x, i
            lo, hi = -np.inf, np.inf
        else:
            lo, hi = nlo, nhi
    slopes.append(_mid(lo, hi))
    return np.asarray(starts, np.int64), np.asarray(slopes)


def _mid(lo: float, hi: float) -> float:
    if not np.isfinite(lo) and not np.isfinite(hi):
        return 0.0                    # single-point segment
    if not np.isfinite(lo):
        return hi
    if not np.isfinite(hi):
        return lo
    return 0.5 * (lo + hi)


@dataclass
class PGMIndex:
    keys: Array
    eps: int
    # per level (leaf level first): segment start keys, slopes, intercepts
    seg_keys: list
    seg_slope: list
    seg_icept: list

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])

    @property
    def n_segments(self) -> int:
        return int(self.seg_keys[0].shape[0])


def build_pgm(keys: Array, eps: int = 64) -> PGMIndex:
    keys_np = np.asarray(keys, np.float64)
    seg_keys, seg_slope, seg_icept = [], [], []
    cur = keys_np
    while True:
        starts, slope = _shrinking_cone(cur, eps)
        icept = starts - slope * cur[starts]     # line through (key[s], s)
        seg_keys.append(jnp.asarray(cur[starts]))
        seg_slope.append(jnp.asarray(slope))
        seg_icept.append(jnp.asarray(icept))
        if starts.size <= 1:
            break
        cur = cur[starts]
    return PGMIndex(keys=jnp.asarray(keys_np), eps=eps, seg_keys=seg_keys,
                    seg_slope=seg_slope, seg_icept=seg_icept)


def lookup(index: PGMIndex, queries: Array) -> Array:
    return _pgm_lookup(index.keys, tuple(index.seg_keys),
                       tuple(index.seg_slope), tuple(index.seg_icept),
                       index.eps, jnp.asarray(queries, jnp.float64))


@functools.partial(jax.jit, static_argnames=("eps",))
def _pgm_lookup(keys, seg_keys: tuple, seg_slope: tuple, seg_icept: tuple,
                eps: int, queries):
    n = keys.shape[0]
    # Descend from the root level (last list entry) to the leaf level.
    seg = jnp.zeros(queries.shape, jnp.int32)
    for lvl in range(len(seg_keys) - 1, 0, -1):
        sk, sl, si = seg_keys[lvl], seg_slope[lvl], seg_icept[lvl]
        pred = sl[seg] * queries + si[seg]
        m = seg_keys[lvl - 1].shape[0]
        lo = jnp.clip(pred.astype(jnp.int32) - eps, 0, m - 1)
        hi = jnp.clip(pred.astype(jnp.int32) + eps + 2, 1, m)
        # rank among next level's start keys: last start <= q.
        # Window is 2*eps+2 wide by the cone bound -> clamp the search depth.
        pos = bounded_search(seg_keys[lvl - 1], queries, lo, hi,
                             iters=_eps_iters(eps))
        nxt = seg_keys[lvl - 1][jnp.clip(pos, 0, m - 1)]
        seg = jnp.where((pos < m) & (nxt == queries), pos,
                        jnp.maximum(pos - 1, 0)).astype(jnp.int32)
    pred = seg_slope[0][seg] * queries + seg_icept[0][seg]
    lo = jnp.clip(pred.astype(jnp.int32) - eps, 0, n - 1)
    hi = jnp.clip(pred.astype(jnp.int32) + eps + 2, 1, n)
    # duplicate-heavy keys can exceed the cone bound (duplicates carry no
    # slope constraint); the verified fallback keeps lookups exact
    return verified_search(keys, queries, lo, hi, iters=_eps_iters(eps))


def _eps_iters(eps: int) -> int:
    """Search depth for a +-eps window (2*eps+2 positions)."""
    from ..kernels.lookup import full_iters
    return full_iters(2 * eps + 2)
