"""Distributed learned-index service + indexed data pipeline
(deliverable (b); DESIGN.md §3 integration).

Runs the range-partitioned shard_map index on 4 simulated devices and the
IndexedDataset ingest path (agile reuse on every new shard).

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/index_service.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import time

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import distributed
from repro.data.indexed_dataset import IndexedDataset

mesh = jax.make_mesh((4,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(3)

# --- sharded index service ------------------------------------------------
keys = jnp.asarray(np.sort(rng.lognormal(0, 1, 1 << 18) * 1e9))
idx = distributed.build_sharded(keys, mesh, n_leaves=256)
lookup = distributed.make_lookup_fn(idx)
q = jnp.asarray(rng.choice(np.asarray(keys), 1 << 14))
r = lookup(q)                      # warm/compile
t0 = time.time()
r = lookup(q).block_until_ready()
dt = time.time() - t0
ok = bool(jnp.all(idx.keys.reshape(-1)[r] == q))
print(f"sharded index: {len(q)} lookups over 4 shards in {dt*1e3:.1f}ms "
      f"(all_to_all routed), exact={ok}")

# --- indexed data pipeline --------------------------------------------------
ds = IndexedDataset.create(eps=0.9, kind="linear", n_leaves=128)
for shard in range(4):
    sk = np.sort(rng.lognormal(0, 0.6, 100_000)) * 1e6 + shard * 1e12
    info = ds.add_shard(sk)
    print(f"shard {shard}: indexed with {info.reuse_fraction:.0%} leaf reuse")
sample = rng.choice(ds.shards[2].keys, 1000)
sid, off = ds.locate(sample)
assert (sid == 2).all()
assert np.allclose(ds.shards[2].keys[off], sample)
print(f"pipeline locate(): exact; mean reuse {ds.mean_reuse:.0%}")
