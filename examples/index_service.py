"""Multi-tenant learned-index service on the batched serving front-end.

Two dynamic sharded indexes of different build sizes serve as tenants of
one ``repro.serve.frontend.BatchingFrontend`` over a 4-device simulated
mesh: requests coalesce up to a 2ms latency budget, pad to pow2 capacity
classes (zero hot-path retraces after warmup), and every tenant answers in
one stacked shard_map dispatch.  A short open-loop Poisson drive reports
the serving SLO — sustained QPS plus p50/p99 latency — alongside the
indexed data-pipeline demo (agile reuse on every new shard).

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/index_service.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import time

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.api import Index
from repro.data.indexed_dataset import IndexedDataset
from repro.serve.frontend import BatchingFrontend, Request, ServeConfig

mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(3)

# --- multi-tenant serving front-end ----------------------------------------
# Tenants build through the unified facade (repro.api.Index): mesh= selects
# the sharded backend, and .backend hands the front-end its tenant object.
tenants, live = [], []
for i, (n, n_leaves) in enumerate(((1 << 16, 256), (1 << 14, 64))):
    keys = np.unique(np.sort(rng.lognormal(0, 1, n) * 1e6 + i * 1e12))
    tenants.append(Index.build(jnp.asarray(keys), mesh=mesh,
                               n_leaves=n_leaves).backend)
    live.append(keys)

with BatchingFrontend(tenants,
                      config=ServeConfig(latency_budget_s=2e-3)) as fe:
    fe.warmup((1, 128))

    # one insert riding the same queue as the finds (applies before the
    # coalesced batch's finds dispatch) — submitted as a typed Request,
    # the primitive every submit_* convenience wrapper funnels through
    extra = np.asarray([live[1][-1] + 7.0, live[1][-1] + 9.0])
    fe.submit(Request(1, "insert", extra)).result(timeout=300.0)
    found, rank = fe.lookup(1, extra)
    assert found.all(), "inserted keys must be visible to the next find"

    # open-loop Poisson drive: 300 point lookups/s for 2s across tenants
    rate, duration = 300.0, 2.0
    gaps = rng.exponential(1.0 / rate, size=int(rate * duration * 2))
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < duration]
    reqs, t0 = [], fe.clock()
    for dt in arrivals:
        lag = (t0 + dt) - fe.clock()
        if lag > 0:
            time.sleep(lag)
        tid = int(rng.random() < 0.3)
        q = rng.choice(live[tid], 1)
        reqs.append((t0 + dt, fe.submit_find(tid, q)))
    for _, r in reqs:
        r.result(timeout=60.0)
    lats = np.asarray([r.done_at - sched for sched, r in reqs]) * 1e3
    span = max(r.done_at for _, r in reqs) - t0
    st = fe.stats
    print(f"serving front-end: {len(reqs)} requests, "
          f"{len(reqs) / span:.0f} QPS sustained (offered {rate:.0f}), "
          f"p50={np.percentile(lats, 50):.1f}ms "
          f"p99={np.percentile(lats, 99):.1f}ms")
    print(f"  {st.batches} stacked dispatches over "
          f"{fe.pack.n_tenants} tenants x 4 shards, capacity classes "
          f"{sorted(st.qcaps)}, pad fraction {st.pad_fraction:.0%}")

# --- indexed data pipeline --------------------------------------------------
ds = IndexedDataset.create(eps=0.9, kind="linear", n_leaves=128)
for shard in range(4):
    sk = np.sort(rng.lognormal(0, 0.6, 100_000)) * 1e6 + shard * 1e12
    info = ds.add_shard(sk)
    print(f"shard {shard}: indexed with {info.reuse_fraction:.0%} leaf reuse")
sample = rng.choice(ds.shards[2].keys, 1000)
sid, off = ds.locate(sample)
assert (sid == 2).all()
assert np.allclose(ds.shards[2].keys[off], sample)
print(f"pipeline locate(): exact; mean reuse {ds.mean_reuse:.0%}")
