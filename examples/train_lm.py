"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic token stream, with checkpointing + restore (deliverable (b)).

Uses yi-9b's family at reduced width so ~100M params fit CPU training.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import os
import tempfile

import repro  # noqa: F401
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="yi-9b")
    args = ap.parse_args()
    ckpt = os.path.join(tempfile.gettempdir(), "repro_train_lm_ckpt")
    # ~100M params: d_model=512, 8 layers, vocab 16k
    losses = train(args.arch, steps=args.steps, batch=4, seq=256, lr=3e-4,
                   reduced=True, d_model=512, n_layers=8, ckpt_dir=ckpt,
                   ckpt_every=max(args.steps // 2, 1))
    assert losses[-1] < losses[0], "loss did not improve"
    print(f"checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
