"""Quickstart: the paper in ~60 lines.

1. Generate the synthetic corpus for eps=0.9 (1,221 datasets, Table 2).
2. Pre-train the whole model pool in one batched program.
3. Index a new "real" dataset by agile model reuse (Algorithm 1).
4. Build RMI-NN-MR and RMRT, run exact lookups through the Pallas kernel.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401
from repro.api import Index
from repro.core import reuse, rmi, rmrt, synth
from repro.kernels import ops

EPS = 0.9

t0 = time.time()
corpus = synth.generate_pool(EPS)
print(f"synthetic corpus: {corpus.size} datasets "
      f"(paper Table 2: 1,221) [{time.time()-t0:.1f}s]")

t0 = time.time()
pool = reuse.build_pool(corpus, kind="mlp", train_steps=400)
print(f"pool pre-trained in ONE batched program [{time.time()-t0:.1f}s]")

# a new dataset arrives (lognormal keys, e.g. item popularities)
rng = np.random.default_rng(7)
keys = jnp.asarray(np.sort(rng.lognormal(0, 0.7, 300_000) * 1e9))

t0 = time.time()
index = rmi.build_rmi(keys, n_leaves=1024, kind="mlp", pool=pool)
print(f"RMI-NN-MR built: {index.reuse_fraction:.0%} of leaves REUSED "
      f"pre-trained models (no training) [{time.time()-t0:.1f}s]")

tree = rmrt.build_rmrt(keys, leaf_cap=4096, fanout=64, kind="linear",
                       pool=reuse.build_pool(corpus, kind="linear"))
print(f"RMRT built: depth={tree.depth}, {tree.num_nodes} nodes, "
      f"reuse={tree.reuse_fraction:.0%}")

# exact lookups
q = jnp.asarray(rng.choice(np.asarray(keys), 10_000))
pos = rmi.lookup(index, q)
assert bool(jnp.all(keys[pos] == q)), "lookup mismatch"
pos2 = rmrt.lookup(tree, q)
assert bool(jnp.all(keys[pos2] == q))
print("RMI + RMRT lookups: exact ✓")

# the Pallas serving kernel (interpret mode on CPU): in-kernel leaf routing
# over the VMEM-resident tables, search depth clamped to the error window
root_blk, mat, vec = index.packed_tables()
qf = q.astype(jnp.float32)   # tracelint: ok[f32-cast](demo runs at f32 resolution)
kf = index.keys.astype(jnp.float32)  # tracelint: ok[f32-cast](same demo cast)
r = ops.index_lookup(qf, root_blk, mat, vec, kf,
                     n_leaves=index.n_leaves, root_kind=index.root_kind,
                     leaf_kind=index.leaf_kind, iters=index.search_iters)
hit = float(jnp.mean((jnp.abs(keys[jnp.clip(r, 0, index.n-1)] - q)
                      / q < 1e-6).astype(jnp.float32)))
print(f"Pallas fused-lookup kernel: {hit:.1%} within f32 resolution ✓")

# the unified dynamic facade (repro.api.Index): one verb set over the
# single-host and sharded backends — find/insert/delete/gather_range —
# with the same pool driving Algorithm-1 reuse on rebuilds
dyn = Index.build(keys[: 1 << 16], n_leaves=256)
extra = np.asarray(keys[: 1 << 16])[-1] + np.asarray([3.0, 7.0])
dyn.insert(extra)
found, rank = dyn.find(extra, path="jnp")
assert bool(jnp.all(found)), "facade must serve fresh inserts"
lo, hi = dyn.find_range(extra[:1], extra[1:])
(span,) = dyn.gather_range(lo, hi)
assert span.size == 2
print(f"repro.api.Index facade: dynamic insert + find + range exact ✓ "
      f"({dyn.live_count} live keys)")
