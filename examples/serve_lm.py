"""Serve a small model with batched requests: prefill + decode loop with
the paged KV cache and the learned page table (deliverable (b)).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs.reduced import reduced
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M
from repro.serve import step as serve_step
from repro.serve.kvcache import PagedKVCache, learned_page_table

ARCH = "qwen3-4b"   # reduced variant: qk_norm + GQA family
B, S_PRE, N_NEW, S_MAX = 4, 48, 16, 128

cfg = reduced(ARCH)
mesh = make_smoke_mesh()
params = M.init_params(cfg, jax.random.PRNGKey(0))

prefill, _ = serve_step.make_prefill(cfg, mesh)
decode, _ = serve_step.make_decode_step(cfg, mesh)

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_PRE)), jnp.int32)
pos = jnp.broadcast_to(jnp.arange(S_PRE)[None], (B, S_PRE)).astype(jnp.int32)

caches = M.init_cache(cfg, B, S_MAX)
t0 = time.time()
logits, caches = prefill(params, caches, prompts, pos)
print(f"prefill B={B} S={S_PRE}: {time.time()-t0:.2f}s "
      f"logits {logits.shape}")

tok = jnp.argmax(logits[:, :cfg.vocab_size], -1).astype(jnp.int32)[:, None]
out = [np.asarray(tok[:, 0])]
t0 = time.time()
for i in range(N_NEW):
    dpos = jnp.full((B, 1), S_PRE + i, jnp.int32)
    nxt, caches = decode(params, caches, tok, dpos,
                         jnp.asarray(S_PRE + i, jnp.int32))
    tok = nxt[:, None]
    out.append(np.asarray(nxt))
dt = time.time() - t0
gen = np.stack(out, 1)
print(f"decoded {N_NEW} tokens x {B} reqs in {dt:.2f}s "
      f"({B*N_NEW/dt:.1f} tok/s on 1 CPU core)")
print("sequences:\n", gen)

# paged KV bookkeeping with the learned page table
pkv = PagedKVCache(n_pages=64, page_size=16, n_kv_heads=cfg.n_kv_heads,
                   head_dim=cfg.head_dim, n_layers=cfg.n_layers)
for req in range(B):
    for blk in range((S_PRE + N_NEW) // 16 + 1):
        pkv.allocate(req, blk)
lookup, keys, pages = learned_page_table(pkv.table)
q = keys[:: max(len(keys) // 8, 1)]
got = lookup(q)
want = pages[jnp.searchsorted(keys, q)]
assert bool(jnp.all(got == want))
print(f"learned page table: {len(pkv.table)} mappings, lookups exact ✓")
